"""repro-lint checker tests (repro.analysis, DESIGN.md SS18).

Each checker gets fixture pairs: a temp-dir project seeded with a
violation (the checker must fire) and the fixed variant (it must stay
silent). Fixtures mirror the real tree's layout (``repro/serving/...``)
because the checkers are project-driven. On top: pragma suppression
semantics, baseline fingerprint stability, a live-tree gate asserting
``src/repro`` is analysis-clean, and runtime regression tests for the
real findings this pass surfaced (sampling params must be static at the
decode jit site; draft host syncs are folded into ServeStats).
"""
import json
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import load_project, run_checkers
from repro.analysis.checkers import (accounting, config_drift, host_sync,
                                     purity, resource)
from repro.analysis.core import (Finding, apply_baseline, load_baseline,
                                 write_baseline)

SRC = Path(__file__).resolve().parent.parent / "src"


def _project(tmp_path, files):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return load_project(tmp_path)


# ------------------------- resource pairing ----------------------------- #

def test_resource_all_paths_leak_fires(tmp_path):
    p = _project(tmp_path, {"repro/serving/loop.py": """
        def serve(kv, rids, t0, ok):
            plan = kv.plan_residency(rids, t0)
            if ok:
                kv.charge_residency(plan)
    """})
    fs = resource.check(p)
    assert len(fs) == 1 and "plan_residency" in fs[0].message
    assert fs[0].qualname == "serve"


def test_resource_all_paths_covered_is_silent(tmp_path):
    p = _project(tmp_path, {"repro/serving/loop.py": """
        def serve(kv, rids, t0, ok):
            plan = kv.plan_residency(rids, t0)
            if ok:
                kv.charge_residency(plan)
            else:
                kv.charge_residency(plan)
    """})
    assert resource.check(p) == []


def test_resource_handler_swallow_fires_and_handler_charge_fixes(tmp_path):
    leaky = """
        def serve(kv, rids, t0):
            plan = kv.plan_residency(rids, t0)
            try:
                validate(plan)
                kv.charge_residency(plan)
            except RuntimeError:
                pass
    """
    fixed = """
        def serve(kv, rids, t0):
            plan = kv.plan_residency(rids, t0)
            try:
                validate(plan)
                kv.charge_residency(plan)
            except RuntimeError:
                kv.charge_residency(plan)
    """
    bad = _project(tmp_path / "a", {"repro/serving/loop.py": leaky})
    assert len(resource.check(bad)) == 1
    assert "exception" in resource.check(bad)[0].message
    ok = _project(tmp_path / "b", {"repro/serving/loop.py": fixed})
    assert resource.check(ok) == []


def test_resource_conduit_return_is_exempt(tmp_path):
    p = _project(tmp_path, {"repro/serving/loop.py": """
        def make_plan(kv, rids, t0):
            return kv.plan_residency(rids, t0)

        def reserve(kv, rid, n):
            r = kv.reserve_ahead(rid, n)
            return r
    """})
    assert resource.check(p) == []


def test_resource_reach_without_release_fires_class_owner_fixes(tmp_path):
    leaky = """
        class Pool:
            def admit(self, kv, rid, n):
                kv.reserve_ahead(rid, n)
                self.live = rid
    """
    fixed = leaky + """
            def drop(self, kv, rid):
                kv.release_reserved(rid)
    """
    bad = _project(tmp_path / "a", {"repro/serving/pool.py": leaky})
    fs = resource.check(bad)
    assert len(fs) == 1 and "reserve_ahead" in fs[0].message
    ok = _project(tmp_path / "b", {"repro/serving/pool.py": fixed})
    assert resource.check(ok) == []


# ----------------------- host-sync discipline --------------------------- #

_SYNC_LEAK = """
    import jax
    import numpy as np

    class Engine:
        def __init__(self, stats):
            self._step = jax.jit(lambda x: x * 2)
            self.stats = stats

        def run(self, x):
            y = self._step(x)
            out = np.asarray(y)
            return out
"""

_SYNC_FIXED = _SYNC_LEAK + """
    class Counted(Engine):
        def run(self, x):
            y = self._step(x)
            out = np.asarray(y)
            self.stats.host_syncs += 1
            return out
"""


def test_host_sync_unaccounted_pull_fires(tmp_path):
    p = _project(tmp_path, {"repro/serving/eng.py": _SYNC_LEAK})
    fs = [f for f in host_sync.check(p) if f.rule == "host-sync"]
    assert len(fs) == 1 and "np.asarray" in fs[0].message
    assert fs[0].qualname == "Engine.run"


def test_host_sync_adjacent_increment_is_silent(tmp_path):
    p = _project(tmp_path, {"repro/serving/eng.py": _SYNC_FIXED})
    fs = [f for f in host_sync.check(p) if f.rule == "host-sync"
          and f.qualname == "Counted.run"]
    assert fs == []


def test_host_sync_asarray_of_host_data_not_flagged(tmp_path):
    p = _project(tmp_path, {"repro/serving/eng.py": """
        import numpy as np

        def digest(latencies):
            arr = np.asarray(latencies)
            return float(arr.mean())
    """})
    assert [f for f in host_sync.check(p) if f.rule == "host-sync"] == []


def test_wall_clock_banned_outside_allowlist(tmp_path):
    p = _project(tmp_path, {
        "repro/serving/clock.py": """
            import time

            def stamp():
                return time.time()
        """,
        "repro/launch/dryrun.py": """
            import time

            def harness():
                return time.time()
        """,
        "repro/serving/virt.py": """
            import time

            def basis():
                return time.perf_counter()
        """,
    })
    fs = [f for f in host_sync.check(p) if f.rule == "wall-clock"]
    assert len(fs) == 1 and fs[0].path == "repro/serving/clock.py"


# ------------------------- traced purity -------------------------------- #

def test_purity_host_branch_on_traced_param_fires(tmp_path):
    p = _project(tmp_path, {"repro/serving/fast.py": """
        import jax

        def step(x):
            if x > 0:
                return x
            return -x

        run = jax.jit(step)
    """})
    fs = purity.check(p)
    assert len(fs) == 1 and "host control flow" in fs[0].message


def test_purity_jnp_where_is_silent(tmp_path):
    p = _project(tmp_path, {"repro/serving/fast.py": """
        import jax
        import jax.numpy as jnp

        def step(x):
            return jnp.where(x > 0, x, -x)

        run = jax.jit(step)
    """})
    assert purity.check(p) == []


def test_purity_static_argname_branch_is_silent(tmp_path):
    p = _project(tmp_path, {"repro/serving/fast.py": """
        import jax
        import jax.numpy as jnp

        def step(x, flag):
            if flag:
                return x * 2
            return x

        run = jax.jit(step, static_argnames=("flag",))
    """})
    assert purity.check(p) == []


def test_purity_banned_randomness_fires(tmp_path):
    p = _project(tmp_path, {"repro/serving/fast.py": """
        import jax
        import numpy as np

        def noisy(x):
            return x * np.random.rand()

        run = jax.jit(noisy)
    """})
    fs = purity.check(p)
    assert len(fs) == 1 and "np.random.rand" in fs[0].message


def test_purity_index_map_side_effects_fire(tmp_path):
    bad = """
        import jax
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def launch(x, table):
            spec = pl.BlockSpec((8, 128), lambda i: (table.pop(), 0))
            return pl.pallas_call(kernel, in_specs=[spec], out_specs=spec,
                                  out_shape=x)(x)
    """
    fixed = bad.replace("lambda i: (table.pop(), 0)", "lambda i: (i, 0)")
    p1 = _project(tmp_path / "a", {"repro/kernels/pal.py": bad})
    fs = purity.check(p1)
    assert any("index_map" in f.message for f in fs)
    p2 = _project(tmp_path / "b", {"repro/kernels/pal.py": fixed})
    assert purity.check(p2) == []


# ---------------------- accounting completeness ------------------------- #

_STATS_TMPL = """
    class ServeStats:
        mystery_total: int = 0

    class Eng:
        def __init__(self):
            self.stats = ServeStats()

        def run(self, trace):
            {write}
            trace.reconcile({reconcile})
"""


def _acct_findings(tmp_path, write, reconcile):
    p = _project(tmp_path, {"repro/serving/engine.py": _STATS_TMPL.format(
        write=write, reconcile=reconcile)})
    return [f for f in accounting.check(p) if "mystery_total" in f.message]


def test_accounting_unwritten_field_fires(tmp_path):
    fs = _acct_findings(tmp_path, "pass", "")
    assert any("never written" in f.message for f in fs)


def test_accounting_unreconciled_unexempt_field_fires(tmp_path):
    fs = _acct_findings(tmp_path, "self.stats.mystery_total += 1", "")
    assert len(fs) == 1 and "neither reconciled" in fs[0].message


def test_accounting_reconciled_field_is_silent(tmp_path):
    fs = _acct_findings(tmp_path, "self.stats.mystery_total += 1",
                        "mystery_total=self.stats.mystery_total")
    assert fs == []


def test_accounting_stale_exemption_fires(tmp_path):
    # the live EXEMPT table names real ServeStats fields; a fixture
    # engine without them turns every entry stale
    p = _project(tmp_path, {"repro/serving/engine.py": _STATS_TMPL.format(
        write="self.stats.mystery_total += 1",
        reconcile="mystery_total=self.stats.mystery_total")})
    stale = [f for f in accounting.check(p) if "stale exemption" in f.message]
    assert len(stale) == len(accounting.EXEMPT)


def test_channel_vocab_unknown_label_and_fstring_fire(tmp_path):
    p = _project(tmp_path, {"repro/serving/tiers.py": """
        GOOD = "ddr->hbs"
        BAD = "ddr->weird"

        def label(src, dst):
            return f"{src}->{dst}"
    """})
    fs = [f for f in accounting.check(p) if f.rule == "channel-vocab"]
    assert len(fs) == 2
    assert any("'ddr->weird'" in f.message for f in fs)
    assert any("f-string" in f.message for f in fs)


# -------------------------- config drift -------------------------------- #

_ENGINE_FIX = """
    class ServeEngine:
        def __init__(self, cfg, page_size=16, dead_knob=0):
            self.cfg = cfg
            self.page_size = page_size
"""

_SERVE_FIX = """
    import argparse
    from repro.serving.engine import ServeEngine

    def main():
        ap = argparse.ArgumentParser()
        ap.add_argument("--page-size", type=int, default=16)
        ap.add_argument("--ghost-knob", type=int, default=0)
        args = ap.parse_args()
        return ServeEngine(object(), page_size=args.page_size, bogus=1)
"""


def test_config_drift_fires_on_all_three_closures(tmp_path):
    p = _project(tmp_path, {"repro/launch/serve.py": _SERVE_FIX,
                            "repro/serving/engine.py": _ENGINE_FIX})
    msgs = [f.message for f in config_drift.check(p)]
    assert any("--ghost-knob" in m for m in msgs)          # unread flag
    assert any("'bogus'" in m for m in msgs)               # unknown kwarg
    assert any("'dead_knob'" in m for m in msgs)           # unused param
    assert len(msgs) == 3


def test_config_drift_silent_when_wired(tmp_path):
    serve = _SERVE_FIX.replace(
        '        ap.add_argument("--ghost-knob", type=int, default=0)\n', ""
    ).replace(", bogus=1", "")
    engine = _ENGINE_FIX.replace(", dead_knob=0", "")
    p = _project(tmp_path, {"repro/launch/serve.py": serve,
                            "repro/serving/engine.py": engine})
    assert config_drift.check(p) == []


# ------------------------ pragmas + baseline ---------------------------- #

def test_pragma_with_justification_suppresses(tmp_path):
    p = _project(tmp_path, {"repro/serving/loop.py": """
        def serve(kv, rids, t0, ok):
            # repro: allow(resource-pairing): fixture exercises suppression
            plan = kv.plan_residency(rids, t0)
            if ok:
                kv.charge_residency(plan)
    """})
    assert run_checkers(p) == []


def test_pragma_without_justification_is_a_finding(tmp_path):
    p = _project(tmp_path, {"repro/serving/loop.py": """
        def serve(kv, rids, t0, ok):
            # repro: allow(resource-pairing)
            plan = kv.plan_residency(rids, t0)
            if ok:
                kv.charge_residency(plan)
    """})
    rules = sorted(f.rule for f in run_checkers(p))
    # the malformed pragma does NOT suppress, and is itself flagged
    assert rules == ["pragma", "resource-pairing"]


def test_pragma_unknown_rule_is_a_finding(tmp_path):
    p = _project(tmp_path, {"repro/serving/ok.py": """
        # repro: allow(made-up-rule): whatever
        X = 1
    """})
    fs = run_checkers(p)
    assert len(fs) == 1 and fs[0].rule == "pragma"
    assert "made-up-rule" in fs[0].message


def test_fingerprint_ignores_line_numbers():
    a = Finding("host-sync", "repro/serving/e.py", 10, "Eng.run", "msg")
    b = Finding("host-sync", "repro/serving/e.py", 99, "Eng.run", "msg")
    c = Finding("host-sync", "repro/serving/e.py", 10, "Eng.run", "other")
    assert a.fingerprint == b.fingerprint != c.fingerprint


def test_baseline_roundtrip_new_and_stale(tmp_path):
    f1 = Finding("host-sync", "repro/serving/e.py", 1, "a", "m1")
    f2 = Finding("host-sync", "repro/serving/e.py", 2, "b", "m2")
    bl = tmp_path / "baseline.json"
    write_baseline(bl, [f1], justification="grandfathered: fixture")
    baseline = load_baseline(bl)
    new, stale = apply_baseline([f1, f2], baseline)
    assert new == [f2] and stale == []
    new, stale = apply_baseline([f2], baseline)
    assert new == [f2] and len(stale) == 1


def test_baseline_entry_requires_justification(tmp_path):
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps({"findings": [
        {"fingerprint": "abc", "rule": "host-sync", "justification": "  "}
    ]}))
    with pytest.raises(ValueError, match="justification"):
        load_baseline(bl)


# --------------------------- live tree gate ----------------------------- #

def test_live_tree_is_analysis_clean():
    """src/repro passes every checker with no pragmas needed beyond those
    committed — the acceptance gate the CI lint lane enforces."""
    findings = run_checkers(load_project(SRC))
    assert findings == [], "\n".join(f.render() for f in findings)


# ----------------- runtime regressions for real findings ---------------- #

@pytest.fixture(scope="module")
def tiny_model():
    import jax
    from repro.configs import get_config
    from repro.configs.reduce import reduced
    from repro.models.api import RuntimeOptions, init_params
    cfg = reduced(get_config("llama3.2-1b"), d_model=64, n_layers=2,
                  vocab=128)
    opts = RuntimeOptions(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    return cfg, opts, params


def test_decode_block_accepts_runtime_temperature(tiny_model):
    """Regression for the traced-purity finding on models/api.py:
    decode_steps branches on temperature/top_k/top_p on the host, so the
    engine's fused-decode jit site must mark them static. Before the fix
    a temperature>0 run through the K>1 block raised a jax
    ConcretizationTypeError."""
    from repro.serving.engine import ServeEngine
    cfg, opts, params = tiny_model
    rng = np.random.default_rng(5)
    reqs = [rng.integers(1, cfg.vocab, size=6).tolist() for _ in range(2)]
    outs = []
    for _ in range(2):
        eng = ServeEngine(cfg, params, opts, max_len=32,
                          scheduler="continuous", page_size=4, max_batch=2,
                          prefill_chunk=8, decode_lookahead=4,
                          temperature=0.8, top_k=8, sample_seed=7)
        outs.append(eng.serve([r[:] for r in reqs], 8))
    assert outs[0] == outs[1]                 # same seed, same tokens
    assert all(len(t) == 8 for t in outs[0])


def test_host_syncs_exact_count_greedy(tiny_model):
    """Regression for the host-sync accounting fix: one sync per prefill
    chunk, one for the first-token pull, one per fused decode block —
    nothing uncounted, nothing double-counted."""
    from repro.serving.engine import ServeEngine
    cfg, opts, params = tiny_model
    req = [[7, 9, 11, 13, 15]]                # 5 tokens, one chunk of 8
    T, K = 16, 8
    eng = ServeEngine(cfg, params, opts, max_len=32,
                      scheduler="continuous", page_size=4, max_batch=1,
                      prefill_chunk=8, decode_lookahead=K)
    eng.serve([req[0][:]], T)
    # 1 chunk + 1 first-token pull + ceil((T-1)/K)=2 blocks
    assert eng.stats.host_syncs == 4


def test_draft_host_syncs_are_folded_into_stats(tiny_model):
    """Regression for the spec-decode accounting fix: ModelDraft's
    per-block device->host pull is drained into ServeStats.host_syncs;
    NGramDraft (pure host) reports zero."""
    from repro.serving import NGramDraft
    from repro.serving.engine import ServeEngine
    assert NGramDraft(max_ngram=3, min_ngram=1).take_host_syncs() == 0

    cfg, opts, params = tiny_model
    rng = np.random.default_rng(11)
    reqs = [rng.integers(1, cfg.vocab, size=6).tolist() for _ in range(2)]

    def syncs(**kw):
        eng = ServeEngine(cfg, params, opts, max_len=32,
                          scheduler="continuous", page_size=4, max_batch=2,
                          prefill_chunk=8, **kw)
        eng.serve([r[:] for r in reqs], 8)
        return eng.stats
    spec = syncs(spec_mode="model", spec_k=4, draft_cfg=cfg)
    assert spec.spec_blocks > 0
    # every verify block pulls once for the target and once for the
    # draft's propose scan: at least 2 syncs per spec block beyond the
    # prefill/first-token constant
    assert spec.host_syncs >= 2 * spec.spec_blocks
