"""Per-assigned-architecture smoke tests: reduced same-family config, one
forward + one train step on CPU, asserting output shapes and finiteness.
The FULL configs are exercised only via the dry-run (ShapeDtypeStructs)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_config
from repro.configs.reduce import reduced
from repro.models import (RuntimeOptions, decode_step, forward, init_cache,
                          init_params, prefill, train_loss)

OPTS = RuntimeOptions(dtype="float32", capacity_factor=8.0)


def _batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    toks = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family in ("vlm", "encdec"):
        P = cfg.prefix_len or cfg.source_len
        batch["prefix_emb"] = jax.random.normal(ks[1], (B, P, cfg.d_model),
                                                jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + PAPER_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0), OPTS)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, _ = forward(cfg, params, batch["tokens"], OPTS,
                        prefix_emb=batch.get("prefix_emb"))
    B, S = batch["tokens"].shape
    exp_S = S + (cfg.prefix_len if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    # one SGD step through jax.grad must stay finite
    def loss_fn(p):
        return train_loss(cfg, p, batch, OPTS)[0]
    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)), "non-finite grads"
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                              params, grads)
    loss2 = loss_fn(new_params)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + PAPER_ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0), OPTS)
    batch = _batch(cfg, jax.random.PRNGKey(1), B=2, S=12)
    B, S = batch["tokens"].shape
    P = cfg.prefix_len if cfg.family == "vlm" else 0
    cache = init_cache(cfg, B, S + P + 8, OPTS)
    lg, cache = prefill(cfg, params, batch["tokens"], cache, OPTS,
                        prefix_emb=batch.get("prefix_emb"))
    assert lg.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(lg).all())
    pos = S + P
    for step in range(2):
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        lg, cache = decode_step(cfg, params, tok, jnp.int32(pos + step),
                                cache, OPTS)
        assert lg.shape == (B, cfg.vocab)
        assert bool(jnp.isfinite(lg).all())


@pytest.mark.parametrize("arch", ["yi-6b", "gemma3-1b", "deepseek-v2-236b",
                                  "zamba2-2.7b", "mamba2-130m",
                                  "whisper-medium", "arctic-480b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits == full-forward logits (serving is
    numerically faithful to training)."""
    cfg = reduced(get_config(arch))
    opts = RuntimeOptions(dtype="float32", capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(0), opts)
    batch = _batch(cfg, jax.random.PRNGKey(1), B=2, S=10)
    toks = batch["tokens"]
    B, S = toks.shape
    P = cfg.prefix_len if cfg.family == "vlm" else 0
    full, _ = forward(cfg, params, toks, opts,
                      prefix_emb=batch.get("prefix_emb"))
    n_pf = 6
    cache = init_cache(cfg, B, S + P, opts)
    lg, cache = prefill(cfg, params, toks[:, :n_pf], cache, opts,
                        prefix_emb=batch.get("prefix_emb"))
    errs = [float(jnp.max(jnp.abs(lg - full[:, P + n_pf - 1])))]
    for t in range(n_pf, S):
        lg, cache = decode_step(cfg, params, toks[:, t], jnp.int32(t + P),
                                cache, opts)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, P + t]))))
    assert max(errs) < 5e-3, errs
